"""SLO layer under overload: priority preemption vs FIFO on the real stack.

A 2-replica paged fleet (2 slots each) is flooded with low-priority
rollouts — ~2x more decode work than the fleet can clear within the
high-priority deadline horizon — while short high-priority requests
(deadline-carrying, e.g. eval/probe traffic) arrive staggered on top.
Driven in deterministic lockstep (latency in *rounds* = parallel hardware
time) with the SLO clock injected as the round counter, so deadlines are
exact and both modes are reproducible:

* ``fifo`` — the SLO layer off: high-priority work waits behind the whole
  flood (classic head-of-line blocking);
* ``slo``  — admission + preemption + watchdog on: a high-priority arrival
  preempts the lowest-priority decode (abort-with-retain — its pages stay
  parked on the replica), admits immediately, and the victim resumes later
  at ZERO re-prefill cost.

Acceptance (asserted here, gated by check_regression):

* high-priority p99 latency improves >= 2x vs FIFO;
* ZERO high-priority deadline misses under SLO;
* preempted low-priority requests resume with zero re-prefilled prefix
  tokens (``client.reprefills == 0`` and total prefill == sum of prompt
  lengths) and byte-identical greedy outputs to the FIFO run.

Emits BENCH_slo.json.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, flush_json
from repro.configs import REGISTRY
from repro.core.llm_proxy import LLMProxy
from repro.core.rollout_client import RolloutClient
from repro.core.router import ProxyRouter
from repro.core.slo import SLOConfig, without_admission
from repro.core.types import (PRIORITY_HIGH, PRIORITY_LOW, PRIORITY_NORMAL,
                              RolloutTask, next_uid)
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine

NUM_REPLICAS = 2
SLOTS_PER_REPLICA = 2
PAGE_SIZE = 16
MAX_TOTAL_LEN = 80
NUM_PAGES = 32
# low-priority flood: mixed budgets, the tail carries most of the work
LOW_BUDGETS = [6] * 20 + [16] * 12 + [48] * 8
NUM_LOW = len(LOW_BUDGETS)
# high-priority probes: short, deadline-carrying, staggered arrivals
NUM_HIGH = 16
HIGH_BUDGET = 4
HIGH_FIRST_ROUND = 2
HIGH_EVERY = 2
HIGH_DEADLINE_ROUNDS = 60
HIGH_DEADLINE_MS = HIGH_DEADLINE_ROUNDS * 1000.0   # clock ticks in rounds
SEEDS = (0,)
MAX_ROUNDS = 5000


def _workload(seed: int):
    rng = np.random.default_rng(seed)
    budgets = np.array(LOW_BUDGETS)
    rng.shuffle(budgets)
    # prompts shorter than one page: the radix cache (off here anyway)
    # could never alias them, so prefill-token accounting is exact
    lows = [(rng.integers(1, 60, int(rng.integers(6, 13))).astype(np.int32),
             int(b)) for b in budgets]
    highs = [(rng.integers(1, 60, int(rng.integers(6, 13))).astype(np.int32),
              HIGH_BUDGET) for _ in range(NUM_HIGH)]
    return lows, highs


def overload_factor(lows, highs) -> float:
    """Offered decode tokens vs fleet capacity within the LAST high's
    deadline horizon — > 1 means FIFO cannot meet the deadlines."""
    offered = sum(b for _, b in lows) + sum(b for _, b in highs)
    horizon = (HIGH_FIRST_ROUND + (NUM_HIGH - 1) * HIGH_EVERY
               + HIGH_DEADLINE_ROUNDS)
    return offered / (horizon * NUM_REPLICAS * SLOTS_PER_REPLICA)


def _run(api, params, lows, highs, mode: str):
    """Lockstep drive of one mode ("fifo" | "slo").  Returns per-class
    latencies (rounds), outputs, and the SLO counters."""
    rounds_box = [0.0]
    slo = SLOConfig(clock=lambda: rounds_box[0]) if mode == "slo" else None
    engines = [PagedDecodeEngine(api, params, num_slots=SLOTS_PER_REPLICA,
                                 max_total_len=MAX_TOTAL_LEN,
                                 page_size=PAGE_SIZE, prefill_chunk=PAGE_SIZE,
                                 num_pages=NUM_PAGES, eos_id=9999,
                                 temperature=0.0, prefix_cache=False)
               for _ in range(NUM_REPLICAS)]
    proxies = [LLMProxy(e, name=f"slo_proxy_{i}", slo=without_admission(slo))
               for i, e in enumerate(engines)]
    router = ProxyRouter(proxies, slo=slo)
    client = RolloutClient(router)

    handles = {}
    submit_round = {}
    finish_round = {}

    def _submit(tag, prompt, budget, priority, deadline_ms):
        # the baseline has no SLO vocabulary: every request is equal class,
        # no deadline — classic FIFO head-of-line blocking
        if mode != "slo":
            priority, deadline_ms = PRIORITY_NORMAL, None
        h = client.submit(RolloutTask(
            task_id=next_uid(), prompt_id=len(handles), replica_idx=0,
            prompt_tokens=prompt, max_new_tokens=budget,
            priority=priority, deadline_ms=deadline_ms))
        handles[tag] = h
        submit_round[tag] = rounds_box[0]
        h.add_done_callback(
            lambda res, tag=tag: finish_round.setdefault(tag, rounds_box[0]))

    t0 = time.perf_counter()
    for i, (prompt, budget) in enumerate(lows):
        _submit(("low", i), prompt, budget, PRIORITY_LOW, None)
    next_high = 0
    rounds = 0
    while any(not h.done() for h in handles.values()) or next_high < NUM_HIGH:
        while (next_high < NUM_HIGH
               and rounds >= HIGH_FIRST_ROUND + next_high * HIGH_EVERY):
            prompt, budget = highs[next_high]
            _submit(("high", next_high), prompt, budget, PRIORITY_HIGH,
                    HIGH_DEADLINE_MS)
            next_high += 1
        for p in proxies:
            p.step_once()
        rounds += 1
        rounds_box[0] = float(rounds)
        assert rounds < MAX_ROUNDS, f"{mode}: workload did not converge"
    wall = time.perf_counter() - t0

    outputs, timed_out = {}, []
    for tag, h in handles.items():
        res = h.result(0)
        if res.aborted:
            timed_out.append(tag)
            continue
        outputs[tag] = list(res.tokens)
    lat = {cls: sorted(finish_round[t] - submit_round[t]
                       for t in handles if t[0] == cls and t in finish_round)
           for cls in ("low", "high")}
    router.fleet_audit()
    result = {
        "rounds": rounds, "wall_s": wall, "outputs": outputs,
        "timed_out": timed_out, "latencies": lat,
        "preemptions": router.preemptions,
        "deadline_misses": router.deadline_misses,
        "long_tail_defers": router.long_tail_defers,
        "reprefills": client.reprefills,
        "migrations": router.migrations,
        "prefill_tokens": sum(e.total_prefill_tokens for e in engines),
    }
    router.stop()
    return result


def _p99(xs) -> float:
    return float(np.percentile(np.asarray(xs, dtype=np.float64), 99))


def run() -> None:
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    results = {"workload": {
        "num_replicas": NUM_REPLICAS, "slots_per_replica": SLOTS_PER_REPLICA,
        "low_budgets": LOW_BUDGETS, "num_high": NUM_HIGH,
        "high_budget": HIGH_BUDGET, "high_deadline_rounds":
        HIGH_DEADLINE_ROUNDS, "seeds": list(SEEDS),
    }}
    ratios = []
    for seed in SEEDS:
        lows, highs = _workload(seed)
        over = overload_factor(lows, highs)
        assert over >= 2.0, f"workload not overloaded enough ({over:.2f}x)"
        fifo = _run(api, params, lows, highs, "fifo")
        slo = _run(api, params, lows, highs, "slo")

        assert not fifo["timed_out"] and not slo["timed_out"], \
            "no request may time out in either mode"
        assert slo["outputs"] == fifo["outputs"], \
            "SLO scheduling must preserve greedy outputs byte-for-byte"
        assert slo["deadline_misses"] == 0, "zero high-priority misses"
        assert slo["preemptions"] >= 1, "overload must trigger preemption"
        assert slo["reprefills"] == 0 and slo["migrations"] == 0, \
            "preempted work must resume in place, never re-prefill"
        prompt_tokens = (sum(len(p) for p, _ in lows)
                         + sum(len(p) for p, _ in highs))
        assert slo["prefill_tokens"] == prompt_tokens, \
            "every prompt prefilled exactly once (zero re-prefill)"

        p99_fifo = _p99(fifo["latencies"]["high"])
        p99_slo = _p99(slo["latencies"]["high"])
        ratio = p99_fifo / p99_slo
        ratios.append(ratio)
        misses_fifo = sum(1 for lat in fifo["latencies"]["high"]
                          if lat > HIGH_DEADLINE_ROUNDS)
        results[f"seed_{seed}"] = {
            "overload_factor": over,
            "fifo": {"p99_high_rounds": p99_fifo,
                     "mean_high_rounds": float(np.mean(
                         fifo["latencies"]["high"])),
                     "would_miss_deadline": misses_fifo,
                     "makespan_rounds": fifo["rounds"]},
            "slo": {"p99_high_rounds": p99_slo,
                    "mean_high_rounds": float(np.mean(
                        slo["latencies"]["high"])),
                    "deadline_misses": slo["deadline_misses"],
                    "preemptions": slo["preemptions"],
                    "long_tail_defers": slo["long_tail_defers"],
                    "reprefills": slo["reprefills"],
                    "makespan_rounds": slo["rounds"]},
            "p99_high_speedup": ratio,
            "outputs_identical": True,
        }
        emit(f"slo.seed{seed}.p99_high_fifo_rounds", p99_fifo,
             f"fifo_would_miss={misses_fifo}/{NUM_HIGH}")
        emit(f"slo.seed{seed}.p99_high_slo_rounds", p99_slo,
             f"preemptions={slo['preemptions']} misses=0 reprefills=0")
        emit(f"slo.seed{seed}.p99_high_speedup", ratio,
             f"overload={over:.2f}x")
    mean_ratio = float(np.mean(ratios))
    results["p99_high_speedup_mean"] = mean_ratio
    emit("slo.p99_high_speedup_mean", mean_ratio, "bound=2.0")
    assert mean_ratio >= 2.0, \
        f"high-priority p99 speedup {mean_ratio:.2f} below the 2x bound"
    flush_json("BENCH_slo.json", results)


if __name__ == "__main__":
    run()
