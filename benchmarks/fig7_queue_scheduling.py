"""Fig 7: queue scheduling + redundant prompts under dynamic filtering.

Paper claims: at 8x8 with 16 additional prompts, per-step generation time
drops 125s -> 37s (3.4x); gains persist at larger batch sizes.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import simulator as S

LEN = S.lognormal_lengths(2_000, 1.0)
KW = dict(group_size=8, k_slots=64, length_sampler=LEN,
          per_token_time=0.004, p_filter=0.5)


def avg(mode, batch_groups, extra, reps=5):
    ts = [S.simulate_filtered_rollout(np.random.default_rng(i), mode=mode,
                                      batch_groups=batch_groups,
                                      extra_prompts=extra, **KW).gen_time
          for i in range(reps)]
    return float(np.mean(ts))


def run() -> None:
    for bg in (8, 16, 32):
        t_batch = avg("batch", bg, 0)
        t_q0 = avg("queue", bg, 0)
        t_q16 = avg("queue", bg, 16)
        emit(f"fig7.b{bg}x8.batch_rollout", t_batch, "")
        emit(f"fig7.b{bg}x8.queue_extra0", t_q0,
             f"speedup={t_batch / t_q0:.2f}")
        emit(f"fig7.b{bg}x8.queue_extra16", t_q16,
             f"speedup={t_batch / t_q16:.2f}")


if __name__ == "__main__":
    run()
