"""Benchmark harness entry: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig1b,fig7,...]

Emits ``name,value,derived`` CSV rows (captured to bench_output.txt by the
final deliverable run).  BENCH_FULL=1 enables the long fig4 training runs.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (bench_engine, bench_fault_tolerance,
                        bench_page_transfer, bench_paged_engine,
                        bench_prefix_cache,
                        bench_prefix_sharing, bench_quant,
                        bench_queue_scheduling,
                        bench_slo, fig1b_throughput_scaling,
                        fig3_allocation_and_rollout, fig4_offpolicy_stability,
                        fig7_queue_scheduling, fig8_prompt_replication,
                        fig9_env_async, fig10_redundant_env,
                        fig11_real_agentic, roofline, table1_async_ratio)
from benchmarks.common import emit, flush_csv

MODULES = [
    ("fig1b", fig1b_throughput_scaling),
    ("fig3", fig3_allocation_and_rollout),
    ("table1", table1_async_ratio),
    ("fig7", fig7_queue_scheduling),
    ("fig8", fig8_prompt_replication),
    ("fig9", fig9_env_async),
    ("fig10", fig10_redundant_env),
    ("fig4", fig4_offpolicy_stability),
    ("fig11", fig11_real_agentic),
    ("engine", bench_engine),
    ("paged_engine", bench_paged_engine),
    ("prefix_sharing", bench_prefix_sharing),
    ("prefix_cache", bench_prefix_cache),
    ("queue_scheduling", bench_queue_scheduling),
    ("page_transfer", bench_page_transfer),
    ("fault_tolerance", bench_fault_tolerance),
    ("slo", bench_slo),
    ("quant", bench_quant),
    ("roofline", roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else None

    for name, mod in MODULES:
        if selected and name not in selected:
            continue
        t0 = time.time()
        print(f"# --- {name} ---")
        mod.run()
        emit(f"_time.{name}_s", time.time() - t0, "")
    flush_csv(args.csv)


if __name__ == "__main__":
    main()
