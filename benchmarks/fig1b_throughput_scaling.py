"""Fig 1b: throughput scaling with GPU count — Async vs Sync-ROLL vs
Sync-Naive, on Base (~2k mean) and Think (~11k mean) response lengths.

Paper claims: Think — async reaches ~7.6x with 8x GPUs, ~2.1x over
sync-naive at 128; Base — sync plateaus, async keeps scaling (2.24x at 128).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BASE_LengthS, THINK_LENGTHS, emit, pipeline_base
from repro.core import simulator as S

GPUS = (16, 32, 64, 128)
STEPS = 12


def run() -> None:
    for model, sampler in (("base", BASE_LengthS), ("think", THINK_LENGTHS)):
        ref_throughput = None
        for g in GPUS:
            naive = S.simulate_pipeline(
                np.random.default_rng(0),
                pipeline_base(gpus=g, mode="sync_naive"), STEPS, sampler)
            roll = S.simulate_pipeline(
                np.random.default_rng(0),
                pipeline_base(gpus=g, mode="sync_queue"), STEPS, sampler)
            asy = S.simulate_pipeline(
                np.random.default_rng(0),
                pipeline_base(gpus=g, mode="async", train_gpus=g // 2,
                              infer_gpus=g // 2, alpha=2), STEPS, sampler)
            if ref_throughput is None:
                ref_throughput = naive.throughput
            emit(f"fig1b.{model}.g{g}.sync_naive", naive.throughput,
                 f"rel={naive.throughput / ref_throughput:.2f}")
            emit(f"fig1b.{model}.g{g}.sync_roll", roll.throughput,
                 f"rel={roll.throughput / ref_throughput:.2f}")
            emit(f"fig1b.{model}.g{g}.async", asy.throughput,
                 f"rel={asy.throughput / ref_throughput:.2f};"
                 f"x_naive={asy.throughput / naive.throughput:.2f};"
                 f"util={asy.gen_utilization:.2f}")


if __name__ == "__main__":
    run()
