"""Fig 4: off-policy algorithm performance under async ratios — REAL
training runs of the full async architecture (engine + proxy + buffer +
controller) on the verifiable arithmetic task.

Paper claims: with alpha in {2, 8}, GRPO-style training with the off-policy
objectives matches the sync baseline's final accuracy.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from benchmarks.common import emit
from repro.configs import REGISTRY
from repro.data.dataset import ArithmeticTask, VOCAB
from repro.launch.pipeline import PipelineSettings, build_rlvr_pipeline

QUICK = os.environ.get("BENCH_FULL", "0") != "1"


def model_cfg():
    return dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=VOCAB)


def run_config(variant: str, alpha: float, steps: int, seed: int = 0,
               rollout_quant: str = "off", tis_clip: float = 0.0):
    task = ArithmeticTask(max_operand=4, ops=("+",), seed=seed)
    s = PipelineSettings(
        async_generation_ratio=alpha, pg_variant=variant,
        rollout_batch_size=16, num_return_sequences_in_group=8,
        num_slots=16, max_new_tokens=4, max_seq_len=16,
        learning_rate=5e-3, seed=seed,
        rollout_quant=rollout_quant, tis_clip=tis_clip)
    pipe = build_rlvr_pipeline(model_cfg(), s, task=task)
    stats = pipe.run(num_steps=steps, timeout=600)
    rewards = [st.reward_mean for st in stats]
    return rewards, max(st.staleness_max for st in stats)


def run() -> None:
    steps = 8 if QUICK else 40
    variants = ("ppo", "tis") if QUICK else \
        ("ppo", "decoupled_ppo", "tis", "cispo", "topr", "weighted_topr")
    alphas = (0.0, 2.0) if QUICK else (0.0, 2.0, 8.0)
    k = max(2, steps // 5)
    for variant in variants:
        for alpha in alphas:
            if alpha > 0 or variant == "ppo":  # sync baseline once per panel
                rewards, stale = run_config(variant, alpha, steps)
                emit(f"fig4.{variant}.alpha{int(alpha)}.final_reward",
                     float(np.mean(rewards[-k:])),
                     f"first={np.mean(rewards[:k]):.3f};max_stale={stale};"
                     f"steps={steps}")
    # FlashRL: int8-quantized rollout engine creates a real train/rollout
    # engine mismatch; sweep with and without the truncated-IS cap that is
    # supposed to absorb it (same budget as one fig4 panel).
    for tis_clip in (0.0, 2.0):
        rewards, stale = run_config("ppo", 2.0, steps,
                                    rollout_quant="int8", tis_clip=tis_clip)
        tag = f"tis{tis_clip:g}" if tis_clip else "notis"
        emit(f"fig4.quant_int8.{tag}.final_reward",
             float(np.mean(rewards[-k:])),
             f"first={np.mean(rewards[:k]):.3f};max_stale={stale};"
             f"steps={steps}")


if __name__ == "__main__":
    run()
