"""Fig 9: environment-level asynchronous rollout vs latency distribution.

Paper claims (simulation): speedup grows with latency std at fixed mean
(1.16x at (10,1) to 2.46x at (10,10), B=512) and shrinks as the mean grows
at fixed std ((50,5) -> 1.20x).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit
from repro.core import simulator as S


def speedup(mu, sigma, batch=512, reps=3):
    ss, aa = [], []
    for i in range(reps):
        cfg = S.AgenticConfig(rollout_batch_size=batch,
                              num_env_groups=batch // 8, group_size=8,
                              k_slots=128, turns=5, env_latency_mu=mu,
                              env_latency_sigma=sigma, env_async=False)
        ss.append(S.simulate_agentic_step(np.random.default_rng(i), cfg))
        aa.append(S.simulate_agentic_step(
            np.random.default_rng(i), dataclasses.replace(cfg, env_async=True)))
    return float(np.mean(ss)), float(np.mean(aa))


def run() -> None:
    # left: sigma sweep at mu=10
    for sigma in (1, 3, 5, 7, 10):
        t_sync, t_async = speedup(10.0, float(sigma))
        emit(f"fig9.mu10_sigma{sigma}.sync", t_sync, "")
        emit(f"fig9.mu10_sigma{sigma}.async", t_async,
             f"speedup={t_sync / t_async:.2f}")
    # right: mu sweep at sigma=5
    for mu in (10, 20, 50):
        t_sync, t_async = speedup(float(mu), 5.0)
        emit(f"fig9.mu{mu}_sigma5.speedup", t_sync / t_async, "")


if __name__ == "__main__":
    run()
