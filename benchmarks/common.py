"""Shared benchmark utilities: CSV/JSON emission + calibrated workloads."""
from __future__ import annotations

import json
import sys

import numpy as np

from repro.core import simulator as S

# Paper setup (§3.2): Qwen3-8B, 32k context, rollout 256, group 32.
# Base model: ~2k mean response length; Think: ~11k mean, heavy tail.
BASE_LengthS = S.lognormal_lengths(2_000, sigma=1.0, max_tokens=32_768)
THINK_LENGTHS = S.lognormal_lengths(11_000, sigma=0.9, max_tokens=32_768)

ROWS: list[tuple] = []


def emit(name: str, value: float, derived: str = "") -> None:
    ROWS.append((name, value, derived))
    print(f"{name},{value:.6g},{derived}")


def pipeline_base(**overrides) -> S.PipelineConfig:
    # paper setup: 256 prompts x 16 returns = 4096 sequences per step
    # (scaled to 2048 to keep the event heap fast), decode slots 16/GPU,
    # rollout:train cost ratio ~3:1 at 32 GPUs (rollout >70% of step time).
    base = dict(rollout_batch_size=2048, group_size=16, gpus=32,
                slots_per_gpu=16, per_token_time=0.004,
                mu_train_per_sample=0.15, train_overhead=20.0,
                weight_sync_time=3.0, alpha=2.0)
    base.update(overrides)
    return S.PipelineConfig(**base)


def flush_csv(path: str | None = None) -> None:
    if path:
        with open(path, "w") as f:
            f.write("name,value,derived\n")
            for n, v, d in ROWS:
                f.write(f"{n},{v},{d}\n")


def flush_json(path: str, payload: dict) -> None:
    """Structured benchmark output (BENCH_*.json) for machine comparison."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=float)
        f.write("\n")
    print(f"wrote {path}")
