"""Table 1: smallest async ratio achieving ~max throughput, swept over
model size (mu_train), sequence length (length distribution), rollout size.

Paper claims: optimal alpha insensitive to model size (2), increases with
seq length (1 -> 2), decreases with rollout size (4 -> 2); alpha=2 suffices.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, pipeline_base
from repro.core import simulator as S

STEPS = 10
ALPHAS = (0.0, 1.0, 2.0, 4.0, 8.0)


def optimal_alpha(make_cfg, sampler, tol=0.05):
    """Smallest alpha whose throughput is within tol of the best."""
    tps = {}
    for a in ALPHAS:
        cfg = make_cfg(a)
        res = S.simulate_pipeline(np.random.default_rng(0), cfg, STEPS, sampler)
        tps[a] = res.throughput
    best = max(tps.values())
    for a in ALPHAS:
        if tps[a] >= (1 - tol) * best:
            return a, tps
    return ALPHAS[-1], tps


def run() -> None:
    # model size ~ per-sample train cost (0.6B..8B)
    for name, mu_t in (("0p6b", 0.08), ("1p7b", 0.2), ("4b", 0.4), ("8b", 0.6)):
        a, tps = optimal_alpha(
            lambda al: pipeline_base(mode="async", gpus=40, train_gpus=24,
                                     infer_gpus=16, alpha=al,
                                     mu_train_per_sample=mu_t),
            S.lognormal_lengths(11_000, 0.9))
        emit(f"table1.model_{name}.opt_alpha", a,
             f"tp@a={tps[a]:.2f};tp@8={tps[8.0]:.2f}")

    # sequence length (mean response length 4k..32k ~ max len proxy)
    for name, mean_len in (("4k", 1_000), ("8k", 2_500), ("16k", 5_500),
                           ("32k", 11_000)):
        a, tps = optimal_alpha(
            lambda al: pipeline_base(mode="async", gpus=40, train_gpus=24,
                                     infer_gpus=16, alpha=al),
            S.lognormal_lengths(mean_len, 0.9, max_tokens=32_768))
        emit(f"table1.len_{name}.opt_alpha", a, "")

    # rollout batch size
    for n in (32, 64, 128, 256):
        a, tps = optimal_alpha(
            lambda al: pipeline_base(mode="async", gpus=40, train_gpus=24,
                                     infer_gpus=16, alpha=al,
                                     rollout_batch_size=n),
            S.lognormal_lengths(11_000, 0.9))
        emit(f"table1.rollout_{n}.opt_alpha", a, "")


if __name__ == "__main__":
    run()
