"""Fig 3a: train/infer GPU allocation sweep at fixed budget (40 GPUs);
Fig 3b: step time vs rollout batch size, Sync-ROLL vs Async.

Paper claims: 24Infer/16Train is optimal (~2x over sync); step time scales
~linearly with rollout size with a constant offset; async wins everywhere.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import THINK_LENGTHS, emit, pipeline_base
from repro.core import simulator as S

STEPS = 10


def run() -> None:
    # --- Fig 3a: allocation sweep, 40 GPUs total
    total = 40
    sync = S.simulate_pipeline(np.random.default_rng(0),
                               pipeline_base(gpus=total, mode="sync_queue"),
                               STEPS, THINK_LENGTHS)
    emit("fig3a.sync_roll.step_time", sync.mean_step_time, "40 GPUs shared")
    best = (None, np.inf)
    for infer in (8, 16, 24, 32):
        train = total - infer
        if train <= 0:
            continue
        asy = S.simulate_pipeline(
            np.random.default_rng(0),
            pipeline_base(gpus=total, mode="async", train_gpus=train,
                          infer_gpus=infer, alpha=2), STEPS, THINK_LENGTHS)
        emit(f"fig3a.async.{infer}infer_{train}train.step_time",
             asy.mean_step_time,
             f"speedup_vs_sync={sync.mean_step_time / asy.mean_step_time:.2f}")
        if asy.mean_step_time < best[1]:
            best = (infer, asy.mean_step_time)
    emit("fig3a.best_infer_allocation", best[0],
         f"step_time={best[1]:.1f}s")

    # --- Fig 3b: rollout batch size sweep
    for n in (32, 64, 128, 256, 512):
        sync = S.simulate_pipeline(
            np.random.default_rng(1),
            pipeline_base(rollout_batch_size=n, gpus=40, mode="sync_queue"),
            STEPS, THINK_LENGTHS)
        asy = S.simulate_pipeline(
            np.random.default_rng(1),
            pipeline_base(rollout_batch_size=n, gpus=40, mode="async",
                          train_gpus=16, infer_gpus=24, alpha=2),
            STEPS, THINK_LENGTHS)
        emit(f"fig3b.n{n}.sync_roll.step_time", sync.mean_step_time, "")
        emit(f"fig3b.n{n}.async.step_time", asy.mean_step_time,
             f"speedup={sync.mean_step_time / asy.mean_step_time:.2f}")


if __name__ == "__main__":
    run()
