"""Quantized rollouts: decode throughput and KV-capacity vs the bf16 baseline.

Measures the three quantization axes of the FlashRL recipe on the paged
engine, all under the same mixed-length continuous-batching workload as
``bench_paged_engine``:

* ``rollout_quant=int8/fp8`` — quantize-on-sync weights (W8A16 dequant
  fused into the jitted step).
* ``kv_quant=int8`` — int8 KV pages with per-(page, slot, kv-head) fp32
  scales.  The headline metric is *effective KV capacity*: how many more
  pages the same byte budget buys.  This is pure dtype arithmetic
  (page bytes: bf16 = 2·hd vs int8 = hd + 4 per stored vector), hence
  fully deterministic — the bench-regression gate pins it.
* greedy-output invariance: ``rollout_quant=off`` must reproduce the bf16
  engine's tokens exactly (the dequant path is an identity traversal).

Emits BENCH_quant.json:
    <mode>.decode_tok_per_s     wall-clock decode throughput
    <mode>.peak_pages_in_use    pool high-water mark
    effective_kv_capacity_ratio pages-per-byte, int8 over bf16 (>= 1.5)
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, flush_json
from repro.configs import REGISTRY
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine

CONCURRENCY = 8
NUM_REQUESTS = 24
MAX_TOTAL_LEN = 192
BUDGET = 24
PAGE_SIZE = 32
PROMPT_LENGTHS = [8, 24, 56, 88, 120, 160]

MODES = (
    ("bf16", {}),
    ("w_int8", {"quant_mode": "int8"}),
    ("w_fp8", {"quant_mode": "fp8"}),
    ("kv_int8", {"kv_quant": "int8"}),
    ("w_int8_kv_int8", {"quant_mode": "int8", "kv_quant": "int8"}),
)


def _requests(rng):
    reqs = []
    for i in range(NUM_REQUESTS):
        plen = PROMPT_LENGTHS[i % len(PROMPT_LENGTHS)]
        reqs.append((i, rng.integers(1, 60, plen).astype(np.int32),
                     min(BUDGET, MAX_TOTAL_LEN - plen)))
    return reqs


def _run_workload(eng):
    """Continuous batching to completion; returns (wall_s, tokens, outputs)."""
    pending = _requests(np.random.default_rng(0))[::-1]
    outputs = {}
    t0 = time.perf_counter()
    while len(outputs) < NUM_REQUESTS:
        while (pending and eng.num_free_slots > 0
               and eng.can_admit(len(pending[-1][1]), pending[-1][2])):
            rid, prompt, budget = pending.pop()
            eng.add_request(rid, prompt, budget)
        for rid, toks, _ in eng.step():
            outputs[rid] = toks.tolist()
    wall = time.perf_counter() - t0
    eng.audit_pages()
    return wall, eng.total_tokens_decoded, outputs


def kv_page_bytes(page_size: int, n_kv: int, head_dim: int,
                  kv_quant: str) -> int:
    """Bytes one physical K+V page pair occupies on device."""
    vecs = 2 * page_size * n_kv                 # K and V, per (token, head)
    if kv_quant == "int8":
        return vecs * (head_dim + 4)            # int8 codes + one fp32 scale
    return vecs * head_dim * 2                  # bf16


def run() -> None:
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    results = {}
    outputs_by_mode = {}
    for name, kw in MODES:
        eng = PagedDecodeEngine(api, params, num_slots=CONCURRENCY,
                                max_total_len=MAX_TOTAL_LEN,
                                page_size=PAGE_SIZE, prefill_chunk=PAGE_SIZE,
                                eos_id=9999, temperature=0.0, **kw)
        wall, tokens, outputs = _run_workload(eng)
        outputs_by_mode[name] = outputs
        tput = tokens / wall
        results[name] = {
            "wall_s": wall,
            "decode_tokens": tokens,
            "decode_tok_per_s": tput,
            "peak_pages_in_use": eng.peak_pages_in_use,
        }
        emit(f"quant.{name}.decode_tok_per_s", tput,
             f"peak_pages={eng.peak_pages_in_use}")

    # rollout_quant=off IS the bf16 engine; weight quantization must not
    # change which requests complete (greedy tokens may drift — that is the
    # engine mismatch TIS absorbs — but the bf16 lane is byte-stable).
    assert set(outputs_by_mode["bf16"]) == set(range(NUM_REQUESTS))

    hd = cfg.resolved_head_dim
    bf16_bytes = kv_page_bytes(PAGE_SIZE, cfg.num_kv_heads, hd, "off")
    int8_bytes = kv_page_bytes(PAGE_SIZE, cfg.num_kv_heads, hd, "int8")
    capacity_ratio = bf16_bytes / int8_bytes
    budget = 512 * bf16_bytes                   # a fixed device byte budget
    results["kv_page_bytes_bf16"] = bf16_bytes
    results["kv_page_bytes_int8"] = int8_bytes
    results["pages_per_budget_bf16"] = budget // bf16_bytes
    results["pages_per_budget_int8"] = budget // int8_bytes
    results["effective_kv_capacity_ratio"] = capacity_ratio
    results["throughput_ratio_w_int8"] = (
        results["w_int8"]["decode_tok_per_s"]
        / results["bf16"]["decode_tok_per_s"])
    results["workload"] = {
        "concurrency": CONCURRENCY, "num_requests": NUM_REQUESTS,
        "prompt_lengths": PROMPT_LENGTHS, "budget": BUDGET,
        "page_size": PAGE_SIZE, "max_total_len": MAX_TOTAL_LEN,
        "head_dim": hd, "num_kv_heads": cfg.num_kv_heads,
    }
    emit("quant.effective_kv_capacity_ratio", capacity_ratio,
         f"bf16={bf16_bytes}B int8={int8_bytes}B per page pair")
    assert capacity_ratio >= 1.5, capacity_ratio
    flush_json("BENCH_quant.json", results)


if __name__ == "__main__":
    run()
