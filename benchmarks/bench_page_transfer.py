"""Cross-replica KV page transfer + fleet-global cache-aware routing.

Three deterministic measurements on the real rollout fleet (lockstep
``step_once`` driving — makespan and prefill counts are placement facts,
never wall clock):

* **cache-aware vs load-only routing** — N=4 replicas, shared-preamble
  traffic (one 48-token system prompt, unique suffixes).  Load-only
  spreads the burst least-loaded, so every replica cold-prefills the
  preamble once; the fleet-global prefix index instead routes follow-ups
  to the replica already holding the preamble while loads allow, and
  PULLS the preamble's pages across before admission when they don't.
  Metric: total prefill tokens, load-only / cache-aware (≥ 1.15 required).
  Greedy outputs must be byte-identical — routing is never semantic.
* **migrated resume** — a decode parked by abort-with-retain on a
  draining replica resumes on the other replica via the page-transfer
  fast path: ZERO re-prefilled tokens, one batched device op per side
  (no per-page dispatch), output byte-identical to uninterrupted.
* **fork batching micro-check** — a COW group fork issues at most one
  batched tail-copy device op per fork (``total_copy_ops`` ≤ forks) while
  moving ≥ 1 page per op.

Emits BENCH_page_transfer.json.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, flush_json
from repro.configs import REGISTRY
from repro.core.llm_proxy import LLMProxy
from repro.core.router import ProxyRouter
from repro.core.rollout_client import RolloutClient
from repro.core.scheduler import expand_tasks
from repro.core.types import RolloutTask, next_uid
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine

NUM_REPLICAS = 4
SLOTS_PER_REPLICA = 2
PAGE_SIZE = 8
PREFILL_CHUNK = 8
MAX_TOTAL_LEN = 96
NUM_REQUESTS = 32
PRE_LEN = 48            # shared preamble (6 pages)
SFX_LEN = 8             # distinct per-request suffix
BUDGET = 6
# load band: small enough that the preamble holder saturates and the miss
# tier (least-loaded + pull) engages — both routing tiers are exercised
AFFINITY_SLACK = 64


def _fleet(api, params, n, **kw):
    base = dict(num_slots=SLOTS_PER_REPLICA, max_total_len=MAX_TOTAL_LEN,
                page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK,
                eos_id=9999, temperature=0.0, prefix_cache=True)
    base.update(kw)
    engines = [PagedDecodeEngine(api, params, **base) for _ in range(n)]
    return engines, [LLMProxy(e, name=f"pt_bench_{i}")
                     for i, e in enumerate(engines)]


def _task(prompt, budget):
    return RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.asarray(prompt, np.int32),
                       max_new_tokens=budget)


def _workload(rng):
    pre = rng.integers(1, 60, PRE_LEN).astype(np.int32)
    return [np.concatenate([pre, rng.integers(1, 60, SFX_LEN)
                            .astype(np.int32)]) for _ in range(NUM_REQUESTS)]


def _pump(proxies, handles):
    rounds = 0
    while not all(h.done() for h in handles.values()):
        if not any(p.step_once() for p in proxies):
            raise AssertionError("fleet idle with undone handles")
        rounds += 1
    return rounds


def _cache_routing(api, params, prompts, *, cache_aware: bool):
    """Warm one replica with the first request, then dispatch the rest
    gated on fleet slots (each placement sees live loads)."""
    engines, proxies = _fleet(api, params, NUM_REPLICAS)
    router = ProxyRouter(proxies, cache_aware=cache_aware,
                         cache_affinity_slack=AFFINITY_SLACK)
    client = RolloutClient(router)
    handles = {0: client.submit(_task(prompts[0], BUDGET))}
    rounds = _pump(proxies, handles)
    todo = list(enumerate(prompts))[1:]
    while todo or not all(h.done() for h in handles.values()):
        submitted = False
        while todo and (sum(not h.done() for h in handles.values())
                        < NUM_REPLICAS * SLOTS_PER_REPLICA):
            i, prompt = todo.pop(0)
            handles[i] = client.submit(_task(prompt, BUDGET))
            submitted = True
        stepped = any(p.step_once() for p in proxies)
        assert stepped or submitted, "fleet idle with undone handles"
        rounds += 1
    for e in engines:
        e.audit_pages()
    router.fleet_audit()
    outputs = {i: list(h.result(0).tokens) for i, h in handles.items()}
    return {
        "makespan_rounds": rounds,
        "prefill_tokens": sum(e.total_prefill_tokens for e in engines),
        "cache_hit_tokens": sum(e.cache_hit_tokens for e in engines),
        "cache_routed": router.cache_routed,
        "cache_pulls": router.cache_pulls,
        "pages_transferred": router.pages_transferred,
        "transfer_bytes": router.transfer_bytes,
        "transfer_device_ops": sum(e.transfer_device_ops for e in engines),
    }, outputs


def _migrated_resume(api, params):
    """Drain the home replica mid-decode, abort-with-retain, and let the
    client continuation migrate the parked pages to the other replica."""
    prompt = np.asarray([2, 9, 4, 3, 7, 11, 5, 8, 6, 1], np.int32)
    budget = 24

    ref = PagedDecodeEngine(api, params, num_slots=1,
                            max_total_len=MAX_TOTAL_LEN, page_size=PAGE_SIZE,
                            prefill_chunk=PREFILL_CHUNK, eos_id=9999,
                            temperature=0.0)
    ref.add_request(0, prompt, budget)
    base = None
    while base is None:
        for _rid, toks, _ in ref.step():
            base = list(toks)

    engines, proxies = _fleet(api, params, 2, prefix_cache=False)
    router = ProxyRouter(proxies)
    versions = [0]
    client = RolloutClient(router, version_fn=lambda: versions[0])
    h = client.submit(_task(prompt, budget), version=0)
    while sum(e.total_tokens_decoded for e in engines) < 4:
        any(p.step_once() for p in proxies)
    home = 0 if engines[0].slots else 1
    other = 1 - home
    prefill_before = engines[other].total_prefill_tokens
    versions[0] = 1
    router.drain(home)
    router.abort_stale(min_version=1, retain=True)
    while not h.done():
        if not any(p.step_once() for p in proxies):
            raise AssertionError("fleet idle with migration pending")
    res = h.result(0)
    for e in engines:
        e.audit_pages()
    assert list(res.tokens) == base, "migrated resume changed greedy output"
    reprefill = engines[other].total_prefill_tokens - prefill_before
    return {
        "reprefill_tokens": int(reprefill),
        "pages_moved": engines[other].pages_transferred_in,
        "transfer_bytes": engines[other].transfer_bytes_in,
        "export_device_ops": engines[home].transfer_device_ops,
        "import_device_ops": engines[other].transfer_device_ops,
        "output_identical": list(res.tokens) == base,
        "migrations": router.migrations,
    }


def _fork_batching(api, params):
    """One COW group: the tail copy must be a single batched device op per
    fork, never one dispatch per page."""
    engines, proxies = _fleet(api, params, 1, num_slots=4,
                              prefix_cache=False)
    client = RolloutClient(ProxyRouter(proxies))
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6, 5, 3], np.int32)
    gh = client.submit_group(expand_tasks(0, prompt, 4, 16, replicate=True))
    handles = dict(enumerate(gh.handles))
    _pump(proxies, handles)
    e = engines[0]
    e.audit_pages()
    return {
        "groups_forked": e.total_groups_forked,
        "copy_ops": e.total_copy_ops,
        "pages_copied": e.total_pages_copied,
    }


def run() -> None:
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompts = _workload(np.random.default_rng(0))

    aware, out_aware = _cache_routing(api, params, prompts, cache_aware=True)
    load, out_load = _cache_routing(api, params, prompts, cache_aware=False)
    identical = out_aware == out_load
    ratio = load["prefill_tokens"] / aware["prefill_tokens"]
    results = {"cache_routing": {
        "cache_aware": aware, "load_only": load,
        "prefill_tokens_ratio": ratio,
        "outputs_identical": bool(identical),
    }}
    emit("page_transfer.routing.prefill_tokens_ratio", ratio,
         f"aware={aware['prefill_tokens']} load={load['prefill_tokens']} "
         f"routed={aware['cache_routed']} pulls={aware['cache_pulls']} "
         f"identical={identical}")
    assert identical, "cache-aware routing changed greedy outputs"
    assert ratio >= 1.15, \
        f"cache-aware prefill reduction below 1.15x: {ratio:.3f}"
    assert aware["cache_routed"] >= 1 and aware["cache_pulls"] >= 1, \
        "both routing tiers must engage on this workload"
    # no per-page dispatch: each pull is one export op + one import op
    assert aware["transfer_device_ops"] <= 2 * aware["cache_pulls"]
    assert aware["pages_transferred"] > aware["cache_pulls"], \
        "pulls must batch multiple pages per device op"
    assert load["cache_routed"] == 0 and load["pages_transferred"] == 0

    mig = _migrated_resume(api, params)
    results["migrated_resume"] = mig
    emit("page_transfer.migrated_resume.reprefill_tokens",
         mig["reprefill_tokens"],
         f"pages={mig['pages_moved']} identical={mig['output_identical']}")
    assert mig["reprefill_tokens"] == 0, \
        "cross-replica migrated resume must re-prefill nothing"
    assert mig["pages_moved"] > 1
    assert mig["export_device_ops"] == 1 and mig["import_device_ops"] == 1, \
        "retained transfer must be one batched device op per side"

    fork = _fork_batching(api, params)
    results["fork_batching"] = fork
    emit("page_transfer.fork.copy_ops", fork["copy_ops"],
         f"groups={fork['groups_forked']} pages={fork['pages_copied']}")
    assert fork["copy_ops"] <= fork["groups_forked"], \
        "fork tail copy must batch into one device op per fork"
    assert fork["pages_copied"] >= fork["copy_ops"]

    results["workload"] = {
        "num_replicas": NUM_REPLICAS, "slots_per_replica": SLOTS_PER_REPLICA,
        "num_requests": NUM_REQUESTS, "preamble_len": PRE_LEN,
        "suffix_len": SFX_LEN, "budget": BUDGET, "page_size": PAGE_SIZE,
        "cache_affinity_slack": AFFINITY_SLACK,
    }
    flush_json("BENCH_page_transfer.json", results)


if __name__ == "__main__":
    run()
