"""Fig 8: prompt replication (num_return_sequences_expand).

Without replication a group of G candidates is ONE request occupying G
co-located slots until its longest member finishes; replication schedules
each candidate independently.  Paper claims: up to 1.84x at 64x16, 1.84x at
16x64; gains grow with batch and group size.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import simulator as S

LEN = S.lognormal_lengths(2_000, 1.0)
K = 16 * 16  # 16 GPUs x 16 slots
PTT = 0.004


def step_time(batch, group, replicate, reps=5):
    ts = []
    for i in range(reps):
        rng = np.random.default_rng(i)
        groups = [LEN(rng, group) * PTT for _ in range(batch)]
        if replicate:
            flat = [d for g in groups for d in g]
            ts.append(S.simulate_queue_completion(flat, K))
        else:
            ts.append(S.simulate_group_queue_completion(groups, K))
    return float(np.mean(ts))


def run() -> None:
    # left panel: vary batch size, num_return_sequences = 16
    for b in (4, 8, 16, 32, 64):
        t_off = step_time(b, 16, False)
        t_on = step_time(b, 16, True)
        emit(f"fig8.b{b}x16.no_replication", t_off, "")
        emit(f"fig8.b{b}x16.replication", t_on,
             f"speedup={t_off / t_on:.2f}")
    # right panel: vary group size, batch = 16
    for g in (4, 8, 16, 32, 64):
        t_off = step_time(16, g, False)
        t_on = step_time(16, g, True)
        emit(f"fig8.16x{g}.no_replication", t_off, "")
        emit(f"fig8.16x{g}.replication", t_on,
             f"speedup={t_off / t_on:.2f}")


if __name__ == "__main__":
    run()
